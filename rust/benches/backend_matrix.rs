//! Backend matrix — every [`ToeplitzOp`] backend timed on every size,
//! against the dispatcher's predictions.
//!
//! One cell per (n, backend): median + p90 apply wall time, relative
//! error vs the exact oracle, and whether the cost-model [`Dispatch`]
//! picks the measured winner for that shape.  The bidirectional cells
//! compare dense / fft / ski (r = n/16, the paper's §3.2 regime); the
//! causal cells compare dense / freq (Hilbert-built spectrum, §3.3).
//! A second table sweeps the **sharded** `apply_batch` at the largest
//! size across worker counts (`--threads 1,2,4`), timing both the
//! per-row ABI and the flat zero-allocation ABI
//! (`apply_batch_flat_sharded`): every cell's output is asserted
//! bitwise identical to the serial reference before being timed, so
//! the speedup column is the tentpole claim — parallel rows, identical
//! bits.  The run also asserts the `fft.real_fast_path` telemetry
//! counter went nonzero: the spectral cells must actually be riding
//! the r2c engine.
//!
//! A final table prices the overload-control ingress: one
//! submit→recv round trip through the bounded admission queue
//! (`server::admission`) at 0/50/90% standing occupancy, so the
//! serving stack's per-request queue overhead is tracked by the same
//! baseline gate as the math kernels.
//!
//! Emits `BENCH_backend_matrix.json` (median + p90 ns/op per cell) so
//! the perf trajectory — and the calibrated crossovers quoted in the
//! README — are tracked across PRs.  `SKI_TNN_BENCH_QUICK=1` shrinks
//! sizes and iteration budgets to CI-smoke scale.
//!
//! Run: `cargo bench --bench backend_matrix [-- --sizes 512,1024,4096,8192 --batch 8 --threads 1,2,4]`

use std::sync::Arc;
use std::time::{Duration, Instant};

use ski_tnn::dsp::{Complex, FftPlan, RealFftPlan};
use ski_tnn::plan::{plan_shape, PlanCache, ShapeKey};
use ski_tnn::runtime::ThreadPool;
use ski_tnn::server::{admission_queue, Admissible, AdmissionPolicy, ServeError, TryRecv};
use ski_tnn::toeplitz::{
    apply_batch_flat_sharded, apply_batch_sharded, build_op, gaussian_kernel, BackendKind,
    Dispatch, DispatchQuery, FftOp, ToeplitzKernel, ToeplitzOp,
};
use ski_tnn::util::bench::{fmt_secs, quick_mode, write_bench_json, Bencher, Table};
use ski_tnn::util::cli::Args;
use ski_tnn::util::json::Json;
use ski_tnn::util::rng::Rng;

/// Build one timed operator through the execution-plan layer (forced
/// backend), the same constructor every serve entry point uses — the
/// bench times exactly what a plan hands out.
fn planned_op(
    dispatch: &Dispatch,
    kernel: &ToeplitzKernel,
    kind: BackendKind,
    n: usize,
    r: usize,
    w: usize,
) -> Arc<dyn ToeplitzOp> {
    let key = ShapeKey {
        n,
        r,
        w,
        causal: kind == BackendKind::Freq,
        threads: 1,
        batch_hint: 1,
        kernel_id: 0,
    };
    let plan = plan_shape(key, dispatch, kind, |k| Arc::from(build_op(kernel, k, r, w)));
    Arc::clone(plan.op())
}

/// Minimal [`Admissible`] request for pricing the admission queue in
/// isolation: a deadline stamp and a no-op rejection sink.
struct Ping {
    deadline: Option<Instant>,
}

impl Admissible for Ping {
    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    fn reject(self, _err: ServeError) {}
}

fn rel_err(got: &[f32], want: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (g, w) in got.iter().zip(want.iter()) {
        num += ((g - w) as f64).powi(2);
        den += (*w as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

fn main() {
    let args = Args::parse(false);
    let quick = quick_mode();
    // Telemetry on for the whole run: the real-FFT fast-path counter
    // asserted at the end only ticks while telemetry is enabled.
    ski_tnn::telemetry::set_enabled(true);
    // Non-pow2 n = 1000 rides in both modes: the length-agnostic
    // serving path is gated by the same baseline as the pow2 rows.
    let default_sizes: &[&str] = if quick {
        &["256", "512", "1000", "1024"]
    } else {
        &["512", "1000", "1024", "4096", "8192"]
    };
    let sizes: Vec<usize> = args
        .list_or("sizes", default_sizes)
        .iter()
        .map(|s| s.parse().expect("--sizes wants integers"))
        .collect();
    let bench = Bencher {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: if quick { 8 } else { 15 },
        budget: Duration::from_millis(if quick { 400 } else { 2000 }),
    };
    let dispatch = Dispatch::default();
    let mut rng = Rng::new(0);
    let mut rows: Vec<Json> = Vec::new();
    let mut agree = 0usize;
    let mut cells = 0usize;

    let mut t = Table::new(
        "backend matrix: median apply time (r = n/16, w = 9)",
        &[
            "n",
            "dense",
            "fft",
            "ski",
            "ski vs fft",
            "winner",
            "dispatch",
            "freq(causal)",
            "causal pick",
        ],
    );
    for &n in &sizes {
        let r = (n / 16).max(2);
        let w = 9usize;
        let scale = n as f64 / 8.0;
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, scale));
        let x = rng.normals(n);
        // Exact oracle: always the dense matvec (one O(n²) pass per
        // size is affordable, and an FFT-based "oracle" would make the
        // fft backend's rel_err a self-comparison).
        let exact = kernel.apply_dense(&x);

        let dense = planned_op(&dispatch, &kernel, BackendKind::Dense, n, r, w);
        let fftop = planned_op(&dispatch, &kernel, BackendKind::Fft, n, r, w);
        let ski = planned_op(&dispatch, &kernel, BackendKind::Ski, n, r, w);
        let causal_kernel = kernel.clone().causal();
        let freq = planned_op(&dispatch, &causal_kernel, BackendKind::Freq, n, r, w);
        let causal_exact = causal_kernel.apply_dense(&x);

        let time = |op: &dyn ToeplitzOp| {
            bench.run(|| {
                std::hint::black_box(op.apply(&x));
            })
        };
        let s_dense = time(dense.as_ref());
        let s_fft = time(fftop.as_ref());
        let s_ski = time(ski.as_ref());
        let s_freq = time(freq.as_ref());

        // Bidirectional cell: measured winner vs dispatcher pick.
        let mut measured = [
            (BackendKind::Dense, s_dense.p50_s),
            (BackendKind::Fft, s_fft.p50_s),
            (BackendKind::Ski, s_ski.p50_s),
        ];
        measured.sort_by(|a, b| a.1.total_cmp(&b.1));
        let winner = measured[0].0;
        let picked =
            dispatch.select(&DispatchQuery { n, r, w, causal: false, batch: 1, threads: 1 });
        cells += 1;
        if winner == picked {
            agree += 1;
        }
        // Causal cell: dense loop vs the Hilbert spectral path.
        let causal_winner =
            if s_dense.p50_s <= s_freq.p50_s { BackendKind::Dense } else { BackendKind::Freq };
        let causal_picked =
            dispatch.select(&DispatchQuery { n, r, w, causal: true, batch: 1, threads: 1 });
        cells += 1;
        if causal_winner == causal_picked {
            agree += 1;
        }

        t.row(&[
            n.to_string(),
            fmt_secs(s_dense.p50_s),
            fmt_secs(s_fft.p50_s),
            fmt_secs(s_ski.p50_s),
            format!("{:.1}×", s_fft.p50_s / s_ski.p50_s),
            winner.name().to_string(),
            picked.name().to_string(),
            fmt_secs(s_freq.p50_s),
            causal_picked.name().to_string(),
        ]);

        for (name, stats, err) in [
            ("dense", &s_dense, rel_err(&dense.apply(&x), &exact)),
            ("fft", &s_fft, rel_err(&fftop.apply(&x), &exact)),
            ("ski", &s_ski, rel_err(&ski.apply(&x), &exact)),
            ("freq", &s_freq, rel_err(&freq.apply(&x), &causal_exact)),
        ] {
            rows.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("r", Json::num(r as f64)),
                ("w", Json::num(w as f64)),
                ("backend", Json::str(name)),
                ("med_ns", Json::num(1e9 * stats.p50_s)),
                ("p90_ns", Json::num(1e9 * stats.p90_s)),
                ("rel_err", Json::num(err)),
                ("winner", Json::str(winner.name())),
                ("dispatch", Json::str(picked.name())),
                ("causal_dispatch", Json::str(causal_picked.name())),
            ]));
        }
        eprintln!(
            "n={n}: ski {} vs fft {} ({:.1}× {}), dispatch {} / winner {}",
            fmt_secs(s_ski.p50_s),
            fmt_secs(s_fft.p50_s),
            s_fft.p50_s / s_ski.p50_s,
            if s_ski.p50_s < s_fft.p50_s { "ski ahead" } else { "fft ahead" },
            picked.name(),
            winner.name()
        );
    }
    t.print();
    println!(
        "\ndispatch agreement: {agree}/{cells} cells picked the measured winner \
         (constants: toeplitz::CostModel::default())"
    );

    // ---- sharded apply_batch: worker sweep at the largest size ----
    // Outputs are asserted bitwise identical to the serial reference
    // before timing — speedup with identical bits is the claim.
    let bn = *sizes.last().unwrap();
    let batch_rows = args.usize_or("batch", 8);
    let threads_list: Vec<usize> = args
        .list_or("threads", &["1", "2", "4"])
        .iter()
        .map(|s| s.parse().expect("--threads wants integers"))
        .collect();
    assert!(!threads_list.is_empty(), "--threads wants at least one worker count");
    let r = (bn / 16).max(2);
    let w = 9usize;
    let scale = bn as f64 / 8.0;
    let kernel = ToeplitzKernel::from_fn(bn, |lag| gaussian_kernel(lag as f64, scale));
    let causal_kernel = kernel.clone().causal();
    let xs: Vec<Vec<f32>> = (0..batch_rows).map(|_| rng.normals(bn)).collect();
    let mut headers: Vec<String> = vec!["backend".into()];
    for &threads in &threads_list {
        headers.push(format!("threads={threads}"));
    }
    headers.push("speedup".into());
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut bt = Table::new(
        &format!(
            "sharded apply_batch: median batch time, per-row / flat ABI \
             (n = {bn}, batch = {batch_rows})"
        ),
        &header_refs,
    );
    // One shared PlanCache over the sweep, exactly like the serving
    // substrate: the `kernel_id` discriminator keys the bidirectional
    // backends apart at an otherwise identical dispatch shape.
    let plans = PlanCache::new(4);
    for kind in [BackendKind::Dense, BackendKind::Fft, BackendKind::Ski, BackendKind::Freq] {
        let k = if kind == BackendKind::Freq { &causal_kernel } else { &kernel };
        let key = ShapeKey {
            n: bn,
            r,
            w,
            causal: kind == BackendKind::Freq,
            threads: *threads_list.last().unwrap(),
            batch_hint: batch_rows,
            kernel_id: kind as u64 + 1,
        };
        let plan = plans.get_or_build(key, || {
            plan_shape(key, &dispatch, kind, |kk| Arc::from(build_op(k, kk, r, w)))
        });
        let op = Arc::clone(plan.op());
        let reference = op.apply_batch(&xs);
        // Flat-ABI twin of the same batch: rows packed in one buffer,
        // asserted bitwise equal to the per-row reference per cell.
        let xs_flat: Vec<f32> = xs.iter().flat_map(|row| row.iter().copied()).collect();
        let reference_flat: Vec<f32> =
            reference.iter().flat_map(|row| row.iter().copied()).collect();
        let mut out_flat = vec![0.0f32; batch_rows * bn];
        let mut cells = vec![op.name().to_string()];
        let mut meds: Vec<(usize, f64)> = Vec::new();
        for &threads in &threads_list {
            let pool = ThreadPool::new(threads);
            let got = apply_batch_sharded(op.as_ref(), &xs, &pool);
            assert_eq!(
                got,
                reference,
                "{} sharded output diverged from serial at {threads} threads",
                op.name()
            );
            out_flat.fill(f32::NAN);
            apply_batch_flat_sharded(op.as_ref(), &xs_flat, batch_rows, &mut out_flat, &pool);
            assert_eq!(
                out_flat,
                reference_flat,
                "{} flat sharded output diverged from per-row at {threads} threads",
                op.name()
            );
            let s = bench.run(|| {
                std::hint::black_box(apply_batch_sharded(op.as_ref(), &xs, &pool));
            });
            let s_flat = bench.run(|| {
                apply_batch_flat_sharded(op.as_ref(), &xs_flat, batch_rows, &mut out_flat, &pool);
                std::hint::black_box(&mut out_flat);
            });
            meds.push((threads, s_flat.p50_s));
            cells.push(format!("{} / {}", fmt_secs(s.p50_s), fmt_secs(s_flat.p50_s)));
            for (abi, stats) in [("per_row", &s), ("flat", &s_flat)] {
                rows.push(Json::obj(vec![
                    ("n", Json::num(bn as f64)),
                    ("r", Json::num(r as f64)),
                    ("w", Json::num(w as f64)),
                    ("backend", Json::str(op.name())),
                    ("abi", Json::str(abi)),
                    ("batch", Json::num(batch_rows as f64)),
                    ("threads", Json::num(threads as f64)),
                    ("med_ns", Json::num(1e9 * stats.p50_s)),
                    ("p90_ns", Json::num(1e9 * stats.p90_s)),
                ]));
            }
        }
        // Speedup = fewest-threads median over most-threads median on
        // the flat ABI (the serve path), independent of the order
        // --threads was given in.
        let lo = meds.iter().min_by_key(|(t, _)| *t).expect("at least one thread count");
        let hi = meds.iter().max_by_key(|(t, _)| *t).expect("at least one thread count");
        cells.push(format!("{:.2}×", lo.1 / hi.1.max(1e-12)));
        bt.row(&cells);
    }
    bt.print();
    let ps = plans.stats();
    println!(
        "plan cache over the sweep: {} builds, {} resident of cap {} \
         ({} bytes after refresh)",
        ps.misses,
        ps.len,
        ps.cap,
        plans.refresh_bytes()
    );
    println!(
        "(bitwise identity across worker counts asserted per cell; dispatch plan at this shape: \
         {:?})",
        dispatch.plan(&DispatchQuery {
            n: bn,
            r,
            w,
            causal: false,
            batch: batch_rows,
            threads: *threads_list.last().unwrap(),
        })
    );

    // ---- native non-pow2 apply vs the old pad-to-next-pow2 path ----
    // The length-agnostic claim, measured: a spectral op built at the
    // native n (its plan picks the cheapest smooth transform length ≥
    // 2n-1) against what a caller previously had to do — zero-extend
    // the kernel and every signal to the next power of two, apply
    // there, truncate.  Construction is excluded from both sides; the
    // pad side's extra copies and larger (or equal) transform are
    // exactly its real per-request cost.
    let pad_sizes: &[usize] = &[96, 360, 769, 1000];
    let mut pt = Table::new(
        "native non-pow2 apply vs pad-to-next-pow2 (fft backend)",
        &["n", "native", "pad→2^k", "speedup", "transform"],
    );
    for &n in pad_sizes {
        let p = n.next_power_of_two();
        let scale = n as f64 / 8.0;
        let kernel = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, scale));
        let x = rng.normals(n);
        let native = FftOp::new(&kernel);
        // The old strategy: the same operator embedded at p, with the
        // missing lags zero (exact on zero-padded inputs).
        let padded_kernel = ToeplitzKernel::from_fn(p, |lag| {
            if lag.unsigned_abs() < n as u64 { kernel.at(lag) } else { 0.0 }
        });
        let padded = FftOp::new(&padded_kernel);
        let s_native = bench.run(|| {
            std::hint::black_box(native.apply(&x));
        });
        let s_pad = bench.run(|| {
            let mut xp = vec![0.0f32; p];
            xp[..n].copy_from_slice(&x);
            let mut y = padded.apply(&xp);
            y.truncate(n);
            std::hint::black_box(y);
        });
        // Same operator on the shared prefix: sanity before timing is
        // trusted.
        {
            let mut xp = vec![0.0f32; p];
            xp[..n].copy_from_slice(&x);
            let y_pad = padded.apply(&xp);
            for (i, (a, b)) in native.apply(&x).iter().zip(y_pad.iter()).enumerate() {
                assert!((a - b).abs() < 1e-3, "n={n} pad/native disagree at {i}: {a} vs {b}");
            }
        }
        pt.row(&[
            n.to_string(),
            fmt_secs(s_native.p50_s),
            fmt_secs(s_pad.p50_s),
            format!("{:.2}×", s_pad.p50_s / s_native.p50_s),
            format!("{} vs {}", native.plan().transform_len(), 2 * p),
        ]);
        for (strategy, stats) in [("native", &s_native), ("pad2", &s_pad)] {
            rows.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("strategy", Json::str(strategy)),
                ("med_ns", Json::num(1e9 * stats.p50_s)),
                ("p90_ns", Json::num(1e9 * stats.p90_s)),
            ]));
        }
        // The acceptance claim: native must beat the padded strategy.
        // Sizes well below the padded transform (96/360/769 run 25-40%
        // fewer transform points) must win outright; n=1000 shares the
        // 2048-point transform with the padded path (2000 vs 2048 is a
        // modeled tie) so its win is only the avoided copy.  The
        // strict ordering is asserted in full mode (stable iteration
        // budgets); quick/CI-smoke mode — tiny budgets on noisy shared
        // runners — warns on an inversion and hard-fails only on a
        // catastrophic (>1.25×) regression, leaving flake absorption
        // to the calibrated bench-check gate over the emitted rows.
        let slack = if native.plan().transform_len() * 10 <= 2 * p * 9 { 1.0 } else { 1.05 };
        if quick {
            if s_native.p50_s >= s_pad.p50_s * slack {
                eprintln!(
                    "WARN: native apply at n={n} ({}) did not beat pad-to-{p} in quick mode: \
                     {} vs {}",
                    native.plan().transform_len(),
                    fmt_secs(s_native.p50_s),
                    fmt_secs(s_pad.p50_s)
                );
            }
            assert!(
                s_native.p50_s < s_pad.p50_s * 1.25,
                "native apply at n={n} catastrophically slower than pad-to-{p}: {} vs {}",
                fmt_secs(s_native.p50_s),
                fmt_secs(s_pad.p50_s)
            );
        } else {
            assert!(
                s_native.p50_s < s_pad.p50_s * slack,
                "native apply at n={n} ({}) must beat pad-to-{p}: {} vs {}",
                native.plan().transform_len(),
                fmt_secs(s_native.p50_s),
                fmt_secs(s_pad.p50_s)
            );
        }
    }
    pt.print();

    // ---- direct odd-length rfft: half-spectrum chirp-z vs the old
    // full-complex fallback ----
    // Circulant grids are always even, so this path only serves
    // direct odd-length `rfft` callers — but for them the chirp-z
    // real plan replaces a full complex engine pass.  n = 1001
    // (7·11·13) is the control: its mixed-radix complex plan is
    // modelled cheaper than the chirp, so `RealFftPlan` keeps the
    // fallback there and the two columns should tie.
    let odd_sizes: &[usize] = &[97, 361, 769, 1001];
    let mut ot = Table::new(
        "odd-length rfft: real plan vs full complex engine",
        &["n", "real plan", "complex", "speedup", "strategy"],
    );
    for &n in odd_sizes {
        let rplan = RealFftPlan::new(n);
        let cplan = FftPlan::new(n);
        let x = rng.normals(n);
        let mut spec: Vec<Complex> = Vec::new();
        let mut scratch: Vec<Complex> = Vec::new();
        rplan.rfft_into(&x, &mut spec, &mut scratch); // warm scratch
        let s_real = bench.run(|| {
            rplan.rfft_into(&x, &mut spec, &mut scratch);
            std::hint::black_box(&spec);
        });
        let mut cbuf: Vec<Complex> = vec![Complex::ZERO; n];
        let s_cplx = bench.run(|| {
            for (c, &v) in cbuf.iter_mut().zip(x.iter()) {
                *c = Complex::new(v as f64, 0.0);
            }
            cplan.fft(&mut cbuf);
            std::hint::black_box(&cbuf);
        });
        ot.row(&[
            n.to_string(),
            fmt_secs(s_real.p50_s),
            fmt_secs(s_cplx.p50_s),
            format!("{:.2}×", s_cplx.p50_s / s_real.p50_s),
            rplan.strategy().to_string(),
        ]);
        for (strategy, stats) in [("rfft_real", &s_real), ("rfft_complex", &s_cplx)] {
            rows.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("strategy", Json::str(strategy)),
                ("med_ns", Json::num(1e9 * stats.p50_s)),
                ("p90_ns", Json::num(1e9 * stats.p90_s)),
            ]));
        }
        // Where the cost gate routed to the odd-real path, the whole
        // point is beating the complex engine — same quick/full
        // discipline as the pad-vs-native assert above.
        if rplan.is_odd_real() {
            if quick {
                if s_real.p50_s >= s_cplx.p50_s {
                    eprintln!(
                        "WARN: odd-real rfft at n={n} did not beat the complex engine in \
                         quick mode: {} vs {}",
                        fmt_secs(s_real.p50_s),
                        fmt_secs(s_cplx.p50_s)
                    );
                }
                assert!(
                    s_real.p50_s < s_cplx.p50_s * 1.25,
                    "odd-real rfft at n={n} catastrophically slower than the complex \
                     engine it replaces: {} vs {}",
                    fmt_secs(s_real.p50_s),
                    fmt_secs(s_cplx.p50_s)
                );
            } else {
                assert!(
                    s_real.p50_s < s_cplx.p50_s,
                    "odd-real rfft at n={n} must beat the complex engine it replaces: \
                     {} vs {}",
                    fmt_secs(s_real.p50_s),
                    fmt_secs(s_cplx.p50_s)
                );
            }
        }
    }
    ot.print();

    // ---- admission queue: serving-stack ingress overhead ----
    // The overload-control layer (`server::admission`) fronts every
    // batcher tick; this table prices one submit→recv round trip
    // through the bounded queue at three standing depths — idle, half
    // full, and near capacity (the `PRESSURE_DOWNSHIFT` regime) —
    // under the soak default policy.  Each timed pair pushes one item
    // and pops one, so depth is held constant across iterations and
    // the medians isolate queue transit cost: the shed/expiry paths
    // only engage at capacity and never fire here.
    let cap = 64usize;
    let policy = AdmissionPolicy::ShedExpiredFirst;
    let budget = Duration::from_millis(250);
    let mut at = Table::new(
        &format!("admission queue: submit→recv round trip (cap = {cap}, {})", policy.name()),
        &["pressure", "depth", "median", "p90", "gauge"],
    );
    for &pct in &[0usize, 50, 90] {
        let (tx, rx) = admission_queue::<Ping>(cap, policy, Some(budget));
        let depth = cap * pct / 100;
        for _ in 0..depth {
            tx.submit(Ping { deadline: Some(Instant::now() + budget) })
                .expect("prefill submit on a live queue");
        }
        let s = bench.run(|| {
            tx.submit(Ping { deadline: Some(Instant::now() + budget) })
                .expect("bench submit on a live queue");
            match rx.try_recv() {
                TryRecv::Item(p) => {
                    std::hint::black_box(&p);
                }
                _ => unreachable!("queue is never empty right after a submit"),
            }
        });
        at.row(&[
            format!("{pct}%"),
            depth.to_string(),
            fmt_secs(s.p50_s),
            fmt_secs(s.p90_s),
            format!("{:.2}", rx.pressure()),
        ]);
        rows.push(Json::obj(vec![
            ("mode", Json::str("admission")),
            ("policy", Json::str(policy.name())),
            ("cap", Json::num(cap as f64)),
            ("pressure_pct", Json::num(pct as f64)),
            ("threads", Json::num(1.0)),
            ("med_ns", Json::num(1e9 * s.p50_s)),
            ("p90_ns", Json::num(1e9 * s.p90_s)),
        ]));
    }
    at.print();

    // Every spectral cell above ran even-length transforms and the
    // odd sweep ran the chirp-z real path, so both fast-path flavours
    // must have fired — a zero counter means the real engine silently
    // fell back to full complex transforms.
    let tele = ski_tnn::telemetry::global();
    let real_fast = tele.counter("fft.real_fast_path").get();
    let packed = tele.counter("fft.real_fast_path.packed").get();
    let odd = tele.counter("fft.real_fast_path.odd").get();
    let fallback = tele.counter("fft.real_fallback").get();
    assert!(real_fast > 0, "fft.real_fast_path counter stayed zero across the spectral sweep");
    assert!(packed > 0, "packed r2c counter stayed zero across the even-length sweep");
    assert!(odd > 0, "odd-real counter stayed zero across the odd rfft sweep");
    println!(
        "fft.real_fast_path transforms this run: {real_fast} \
         (packed {packed}, odd {odd}; complex fallback {fallback})"
    );

    match write_bench_json("backend_matrix", rows) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write BENCH_backend_matrix.json: {e}"),
    }
}
