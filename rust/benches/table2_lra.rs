//! Table 2 (speed companion) — LRA per-task training-step speed for
//! TNN vs SKI-TNN vs FD-TNN.
//!
//! The paper's Table 2 reports accuracy (regenerate with
//! `cargo run --release --example train_lra`); the speed side of the
//! same trade-off (their Fig 1a) is measured here: steps/sec and peak
//! RSS per config at the LRA sequence length (n = 1024; 2-D tasks use
//! the smaller r=32/m=16 SKI layers, as in the paper).
//!
//! Run: `cargo bench --bench table2_lra [-- --steps N --tasks text,image]`

mod common;

use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    common::run_child_if_requested();
    let args = Args::parse(false);
    let steps = args.usize_or("steps", 6);
    let tasks = args.list_or(
        "tasks",
        &["text", "listops", "retrieval", "pathfinder", "image"],
    );

    let mut t = Table::new(
        "Table 2 / Fig 1a speed: LRA training steps/sec (n = 1024)",
        &["task", "TNN it/s", "SKI it/s", "FD it/s", "SKI vs TNN", "FD vs TNN", "RSS T/S/F MB"],
    );
    for task in &tasks {
        eprintln!("measuring lra_{task}_* ({steps} steps each)...");
        let b = common::measure(&format!("lra_{task}_base"), steps)?;
        let s = common::measure(&format!("lra_{task}_ski"), steps)?;
        let f = common::measure(&format!("lra_{task}_fd"), steps)?;
        t.row(&[
            task.clone(),
            format!("{:.2}", b.steps_per_sec),
            format!("{:.2}", s.steps_per_sec),
            format!("{:.2}", f.steps_per_sec),
            common::speedup_pct(b.ms_per_step, s.ms_per_step),
            common::speedup_pct(b.ms_per_step, f.ms_per_step),
            format!("{:.0}/{:.0}/{:.0}", b.peak_rss_mb, s.peak_rss_mb, f.peak_rss_mb),
        ]);
    }
    t.print();
    println!("(accuracy grid: `cargo run --release --example train_lra -- --steps 200`)");
    Ok(())
}
