//! Fig 7a — perplexity vs inference length for the causal models.
//!
//! Trains TNN and FD-TNN briefly at n = 256, then evaluates through
//! the `fwd_n{64,128,384,512}` artifacts: the FD RPE is re-sampled at
//! finer frequency resolution for longer n (the paper's extrapolation
//! mechanism), so PPL should stay flat-ish rather than blow up beyond
//! the training length, and FD ≈ TNN at every length.
//!
//! Run: `cargo bench --bench fig7_ppl_vs_len [-- --steps 100]`

mod common;

use std::sync::Arc;

use ski_tnn::config::RunConfig;
use ski_tnn::coordinator::{evaluate, Trainer};
use ski_tnn::data::{BatchSource, CausalLmStream, Corpus, Split};
use ski_tnn::runtime::Engine;
use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    common::run_child_if_requested();
    let args = Args::parse(false);
    let steps = args.usize_or("steps", 60);
    let eval_batches = args.usize_or("eval-batches", 4);
    let corpus_bytes = args.usize_or("corpus-bytes", 1 << 20);
    let seed = args.u64_or("seed", 0);

    let engine = Engine::new("artifacts")?;
    let corpus = Arc::new(Corpus::generate(seed, corpus_bytes).tokens());
    let lens = [64usize, 128, 256, 384, 512];

    let mut t = Table::new(
        &format!("Fig 7a: val PPL vs inference length after {steps} steps at n=256"),
        &["config", "n=64", "n=128", "n=256", "n=384", "n=512"],
    );
    for config in ["lm_base_3l", "lm_fd_3l"] {
        eprintln!("training {config} for {steps} steps...");
        let run = RunConfig {
            config: config.into(),
            steps,
            eval_every: 0,
            eval_batches,
            corpus_bytes,
            seed,
            log_every: 0,
            ..RunConfig::default()
        };
        let mut trainer = Trainer::new(&engine, run)?;
        trainer.train()?;
        let cfg = engine.config(config)?;
        let mut cells = vec![config.to_string()];
        for len in lens {
            let entry = if len == cfg.n { "fwd".to_string() } else { format!("fwd_n{len}") };
            let mut src: Box<dyn BatchSource> = Box::new(CausalLmStream::new(
                corpus.clone(),
                Split::Val,
                cfg.batch,
                len,
                seed + 1,
            ));
            let stats = evaluate(&engine, &trainer.state, &entry, src.as_mut(), eval_batches)?;
            cells.push(format!("{:.2}", stats.ppl));
        }
        t.row(&cells);
    }
    t.print();
    println!("paper shape: FD-TNN ≈ TNN at every length; both degrade gracefully past n=256");
    Ok(())
}
