//! Fig 10 — wall-clock per step and memory vs sequence length:
//! SKI-TNN vs the 6-layer-RPE TNN baseline at n ∈ {512, 2048}.
//!
//! Paper claims at these lengths: ~25% / ~30% time-per-step reduction
//! and ~17% / ~42% memory reduction for SKI.  The timing configs
//! (`t512_*`, `t2048_*`) keep the paper's structure (6-layer RPE for
//! the baseline, r=64/m=32 SKI) at widths that make CPU steps tractable.
//!
//! Run: `cargo bench --bench fig10_seqlen_scaling [-- --steps N]`

mod common;

use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    common::run_child_if_requested();
    let args = Args::parse(false);
    let steps = args.usize_or("steps", 5);

    let mut t = Table::new(
        "Fig 10: step time & peak memory vs sequence length — TNN-6L vs SKI",
        &["n", "TNN ms", "SKI ms", "time cut", "TNN MB", "SKI MB", "mem cut", "paper"],
    );
    for (n, base, ski, paper) in [
        (512, "t512_base6", "t512_ski", "-25% t, -17% m"),
        (2048, "t2048_base6", "t2048_ski", "-30% t, -42% m"),
    ] {
        eprintln!("measuring n={n} ({steps} steps each)...");
        let b = common::measure(base, steps)?;
        let s = common::measure(ski, steps)?;
        t.row(&[
            n.to_string(),
            format!("{:.0}", b.ms_per_step),
            format!("{:.0}", s.ms_per_step),
            format!("{:+.1}%", 100.0 * (s.ms_per_step / b.ms_per_step - 1.0)),
            format!("{:.0}", b.peak_rss_mb),
            format!("{:.0}", s.peak_rss_mb),
            format!("{:+.1}%", 100.0 * (s.peak_rss_mb / b.peak_rss_mb - 1.0)),
            paper.to_string(),
        ]);
    }
    t.print();
    Ok(())
}
