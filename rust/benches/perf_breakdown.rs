//! §Perf harness — where does a fused train step actually spend time?
//!
//! Splits one step into the L3-visible phases:
//!   1. batch generation (host, prefetchable),
//!   2. HostTensor → XLA literal conversion,
//!   3. `execute` (the XLA computation — L2/L1 territory),
//!   4. output tuple pull + decompose (host),
//! and reports each as ms and % of step. L3's job is to make 1, 2 and 4
//! vanish next to 3; the prefetcher already moves 1 off the step path
//! (measured here both ways).  Also prints per-entry compile times and
//! the HLO op-count analysis (FFT/dot counts per TNO variant) that
//! backs the L2 §Perf claims in EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench perf_breakdown [-- --steps N]`

use std::sync::Arc;
use std::time::Instant;

use ski_tnn::coordinator::{batch_for, to_literals, Prefetcher};
use ski_tnn::data::{Corpus, Split};
use ski_tnn::runtime::{Engine, ModelState};
use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn count_ops(path: &str, op: &str) -> usize {
    std::fs::read_to_string(path)
        .map(|s| s.matches(&format!(" {op}(")).count())
        .unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(false);
    let steps = args.usize_or("steps", 20);
    let config = args.str_or("config", "lm_fd_3l");

    let engine = Engine::new("artifacts")?;
    let corpus = Arc::new(Corpus::generate(0, 400_000).tokens());

    let mut state = ModelState::init(&engine, &config, 0)?;
    engine.load(&config, "step")?;

    // ---- phase breakdown, synchronous (no prefetch) ----
    let mut src = batch_for(&engine, &config, Split::Train, Some(corpus.clone()), 1)?;
    let (mut t_gen, mut t_conv, mut t_exec) = (0.0f64, 0.0f64, 0.0f64);
    // warmup
    state.step(&to_literals(&src.next_batch())?)?;
    let t_all = Instant::now();
    for _ in 0..steps {
        let t0 = Instant::now();
        let host = src.next_batch();
        let t1 = Instant::now();
        let lits = to_literals(&host)?;
        let t2 = Instant::now();
        state.step(&lits)?; // execute + output pull/decompose
        let t3 = Instant::now();
        t_gen += (t1 - t0).as_secs_f64();
        t_conv += (t2 - t1).as_secs_f64();
        t_exec += (t3 - t2).as_secs_f64();
    }
    let total = t_all.elapsed().as_secs_f64();

    let mut t = Table::new(
        &format!("{config}: fused-step phase breakdown ({steps} steps, no prefetch)"),
        &["phase", "ms/step", "% of step"],
    );
    for (name, secs) in
        [("batch gen (host)", t_gen), ("literal conv", t_conv), ("execute+pull", t_exec)]
    {
        t.row(&[
            name.to_string(),
            format!("{:.2}", 1e3 * secs / steps as f64),
            format!("{:.1}%", 100.0 * secs / total),
        ]);
    }
    t.row(&["total".into(), format!("{:.2}", 1e3 * total / steps as f64), "100%".into()]);
    t.print();

    // ---- with prefetch (the production loop) ----
    let src2 = batch_for(&engine, &config, Split::Train, Some(corpus), 2)?;
    let prefetch = Prefetcher::spawn(src2, 4);
    state.step(&to_literals(&prefetch.next()?)?)?; // warm
    let t0 = Instant::now();
    for _ in 0..steps {
        state.step(&to_literals(&prefetch.next()?)?)?;
    }
    let with_pf = t0.elapsed().as_secs_f64();
    println!(
        "prefetch ON: {:.2} ms/step vs {:.2} sync ({:+.1}%)\n",
        1e3 * with_pf / steps as f64,
        1e3 * total / steps as f64,
        100.0 * (with_pf / total - 1.0),
    );

    // ---- compile-time log ----
    let mut t = Table::new("compile times (one-off per process)", &["entry", "seconds"]);
    for (k, s) in engine.compile_log() {
        t.row(&[k, format!("{s:.1}")]);
    }
    t.print();

    // ---- L2 op-count analysis: FD saves kernel-side work ----
    let mut t = Table::new(
        "HLO op counts in the lowered fwd graphs (L2 analysis)",
        &["config", "fft", "dot", "multiply", "bytes"],
    );
    for c in ["lm_base_3l", "lm_fd_3l", "lm_bidir_base_3l", "lm_bidir_fd_3l", "lm_bidir_ski"] {
        let path = format!("artifacts/{c}.fwd.hlo.txt");
        t.row(&[
            c.to_string(),
            count_ops(&path, "fft").to_string(),
            count_ops(&path, "dot").to_string(),
            count_ops(&path, "multiply").to_string(),
            std::fs::metadata(&path).map(|m| m.len().to_string()).unwrap_or_default(),
        ]);
    }
    t.print();
    println!("(bidir FD lowers fewer FFTs than bidir base — the paper's 'one fewer FFT';");
    println!(" SKI lowers none on the kernel side: conv + matmul only.)");
    Ok(())
}
