//! Pure-Rust substrate micro-benchmarks — the paper's asymptotic
//! arguments measured directly, without XLA in the way:
//!
//! * Toeplitz apply: dense O(n²) vs circulant-FFT O(n log n) — the
//!   baseline TNN's core trick and its crossover point.
//! * SKI apply: the mathematically O(n + r log r) sparse path vs the
//!   dense-matmul path the paper actually ships (§3.2.1's "sparse
//!   tensors are slower than dense below n ≈ 512" observation).
//! * Appendix B: the causal-SKI cumulative-sum scan vs the plain FFT
//!   apply — the sequential dependency that makes causal SKI a loss,
//!   motivating the paper's switch to frequency-domain causality.
//!
//! Run: `cargo bench --bench substrate_microbench [-- --full]`

use ski_tnn::toeplitz::{causal_ski_scan, gaussian_kernel, Ski, ToeplitzKernel};
use ski_tnn::util::bench::{fmt_secs, Bencher, Table};
use ski_tnn::util::cli::Args;
use ski_tnn::util::rng::Rng;

fn main() {
    let args = Args::parse(false);
    let sizes: &[usize] =
        if args.flag("full") { &[256, 1024, 4096, 16384, 65536] } else { &[256, 1024, 4096] };
    let bench = Bencher::quick();
    let mut rng = Rng::new(0);

    // ---------------- Toeplitz dense vs FFT ----------------
    let mut t = Table::new(
        "Toeplitz apply: dense O(n²) vs circulant FFT O(n log n)",
        &["n", "dense", "fft", "fft speedup"],
    );
    for &n in sizes {
        let k = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 16.0));
        let x = rng.normals(n);
        let dense = if n <= 4096 {
            Some(bench.run(|| {
                std::hint::black_box(k.apply_dense(&x));
            }))
        } else {
            None // O(n²) beyond patience at 16k+
        };
        let fft = bench.run(|| {
            std::hint::black_box(k.apply_fft(&x));
        });
        t.row(&[
            n.to_string(),
            dense.as_ref().map(|d| fmt_secs(d.mean_s)).unwrap_or_else(|| "—".into()),
            fmt_secs(fft.mean_s),
            dense
                .as_ref()
                .map(|d| format!("{:.1}×", d.mean_s / fft.mean_s))
                .unwrap_or_else(|| "—".into()),
        ]);
    }
    t.print();

    // ---------------- SKI sparse vs dense path ----------------
    let r = 64;
    let mut t = Table::new(
        "SKI apply (r = 64): O(n + r log r) sparse path vs dense-matmul path",
        &["n", "sparse path", "dense path", "sparse speedup", "vs full FFT"],
    );
    for &n in sizes {
        let ski = Ski::from_kernel(n, r, |t| gaussian_kernel(t, n as f64 / 16.0));
        let full = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 16.0));
        let x = rng.normals(n);
        let sp = bench.run(|| {
            std::hint::black_box(ski.apply_sparse(&x));
        });
        let de = bench.run(|| {
            std::hint::black_box(ski.apply_dense(&x));
        });
        let ff = bench.run(|| {
            std::hint::black_box(full.apply_fft(&x));
        });
        t.row(&[
            n.to_string(),
            fmt_secs(sp.mean_s),
            fmt_secs(de.mean_s),
            format!("{:.1}×", de.mean_s / sp.mean_s),
            format!("{:.1}× vs {}", ff.mean_s / sp.mean_s, fmt_secs(ff.mean_s)),
        ]);
    }
    t.print();

    // ---------------- Appendix B: causal SKI scan ----------------
    let mut t = Table::new(
        "Appendix B: causal-SKI cumulative scan vs (bidirectional) FFT apply",
        &["n", "causal scan", "fft apply", "scan penalty"],
    );
    for &n in sizes {
        let ski = Ski::from_kernel(n, r, |t| gaussian_kernel(t, n as f64 / 16.0));
        let full = ToeplitzKernel::from_fn(n, |lag| gaussian_kernel(lag as f64, n as f64 / 16.0));
        let x = rng.normals(n);
        let scan = bench.run(|| {
            std::hint::black_box(causal_ski_scan(&ski, &x));
        });
        let fft = bench.run(|| {
            std::hint::black_box(full.apply_fft(&x));
        });
        t.row(&[
            n.to_string(),
            fmt_secs(scan.mean_s),
            fmt_secs(fft.mean_s),
            format!("{:.1}× slower", scan.mean_s / fft.mean_s),
        ]);
    }
    t.print();
    println!("paper shape: SKI ≫ FFT bidirectionally, but the causal scan loses to FFT —");
    println!("exactly why §3.3 switches to Hilbert-transform causality in frequency domain.");
}
