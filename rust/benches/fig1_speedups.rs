//! Fig 1b — pre-training speedups (iterations/sec) for causal and
//! bidirectional models at both RPE depths, FD-TNN (and SKI-TNN) vs
//! the TNN baseline.
//!
//! Paper claim: FD-TNN gains 5-15% causal and 35-80% bidirectional
//! (the bidirectional path saves the kernel FFT *and* the decay bias;
//! the causal path still pays the Hilbert-transform FFT pair).
//!
//! With `--lra`, also measures the per-task LRA training speed that
//! forms the x-axis of Fig 1a (accuracy axis: `example train_lra`).
//!
//! Run: `cargo bench --bench fig1_speedups [-- --steps N --lra]`

mod common;

use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    common::run_child_if_requested();
    let args = Args::parse(false);
    let steps = args.usize_or("steps", 8);

    let rows = [
        ("causal 3L", "lm_base_3l", "lm_fd_3l"),
        ("causal 6L", "lm_base_6l", "lm_fd_6l"),
        ("bidir 3L", "lm_bidir_base_3l", "lm_bidir_fd_3l"),
        ("bidir 6L", "lm_bidir_base_6l", "lm_bidir_fd_6l"),
    ];
    let mut t = Table::new(
        "Fig 1b: pre-training iterations/sec — FD-TNN vs TNN",
        &["setting", "TNN it/s", "FD it/s", "FD speedup"],
    );
    for (label, base, fd) in rows {
        eprintln!("measuring {base} vs {fd}...");
        let mb = common::measure(base, steps)?;
        let mf = common::measure(fd, steps)?;
        t.row(&[
            label.to_string(),
            format!("{:.2}", mb.steps_per_sec),
            format!("{:.2}", mf.steps_per_sec),
            common::speedup_pct(mb.ms_per_step, mf.ms_per_step),
        ]);
    }
    // SKI-TNN is MLP-free, bidirectional-only (the paper's Fig 1b note)
    eprintln!("measuring lm_bidir_ski...");
    let base = common::measure("lm_bidir_base_6l", steps)?;
    let ski = common::measure("lm_bidir_ski", steps)?;
    t.row(&[
        "bidir SKI vs 6L".into(),
        format!("{:.2}", base.steps_per_sec),
        format!("{:.2}", ski.steps_per_sec),
        common::speedup_pct(base.ms_per_step, ski.ms_per_step),
    ]);
    t.print();

    if args.flag("lra") {
        let mut t = Table::new(
            "Fig 1a (speed axis): LRA step time ms (bubble size: peak RSS MB)",
            &["task", "TNN", "SKI", "FD", "SKI speedup", "FD speedup"],
        );
        for task in ["text", "listops", "retrieval", "pathfinder", "image"] {
            eprintln!("measuring lra_{task}_*...");
            let b = common::measure(&format!("lra_{task}_base"), steps)?;
            let s = common::measure(&format!("lra_{task}_ski"), steps)?;
            let f = common::measure(&format!("lra_{task}_fd"), steps)?;
            t.row(&[
                task.to_string(),
                format!("{:.0} ({:.0}M)", b.ms_per_step, b.peak_rss_mb),
                format!("{:.0} ({:.0}M)", s.ms_per_step, s.peak_rss_mb),
                format!("{:.0} ({:.0}M)", f.ms_per_step, f.peak_rss_mb),
                common::speedup_pct(b.ms_per_step, s.ms_per_step),
                common::speedup_pct(b.ms_per_step, f.ms_per_step),
            ]);
        }
        t.print();
    }
    Ok(())
}
