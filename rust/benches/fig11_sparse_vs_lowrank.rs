//! Fig 11 — SKI ablation: low-rank-only vs sparse + low-rank.
//!
//! Paper finding: the low-rank branch is the primary cost in both time
//! and memory, but the sparse branch (the depthwise 1-D conv) still
//! adds a visible share of the step time while contributing almost no
//! memory.  The `*_ski_lronly` configs drop the conv branch from the
//! lowered graph (`ski_lowrank_only=True`), so the delta is exactly
//! the conv's cost inside the fused train step.
//!
//! Run: `cargo bench --bench fig11_sparse_vs_lowrank [-- --steps N]`

mod common;

use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    common::run_child_if_requested();
    let args = Args::parse(false);
    let steps = args.usize_or("steps", 5);

    let mut t = Table::new(
        "Fig 11: SKI-TNN step cost — low-rank only vs sparse + low-rank",
        &["n", "low-rank ms", "sparse+LR ms", "conv share", "LR MB", "S+LR MB"],
    );
    for (n, lronly, both) in
        [(512, "t512_ski_lronly", "t512_ski"), (2048, "t2048_ski_lronly", "t2048_ski")]
    {
        eprintln!("measuring n={n}...");
        let l = common::measure(lronly, steps)?;
        let b = common::measure(both, steps)?;
        t.row(&[
            n.to_string(),
            format!("{:.0}", l.ms_per_step),
            format!("{:.0}", b.ms_per_step),
            format!("{:.1}%", 100.0 * (b.ms_per_step - l.ms_per_step) / b.ms_per_step),
            format!("{:.0}", l.peak_rss_mb),
            format!("{:.0}", b.peak_rss_mb),
        ]);
    }
    t.print();
    println!("paper shape: low-rank dominates both axes; conv adds time, ~no memory");
    Ok(())
}
