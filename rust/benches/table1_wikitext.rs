//! Table 1 (speed rows) — causal pre-training step time, TNN vs FD-TNN.
//!
//! The paper: "at sequence length 512 with a six layer RPE, FD-TNN is
//! 15% faster than the baseline TNN; for a three layer RPE, 10%".
//! This harness measures fused-train-step time for the causal configs
//! at both RPE depths and prints the same comparison.  (The quality
//! rows of Table 1 — perplexities — come from the end-to-end driver:
//! `cargo run --release --example train_lm`; see EXPERIMENTS.md.)
//!
//! Run: `cargo bench --bench table1_wikitext [-- --steps N]`

mod common;

use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    common::run_child_if_requested();
    let args = Args::parse(false);
    let steps = args.usize_or("steps", 8);

    let pairs = [
        ("3-layer RPE", "lm_base_3l", "lm_fd_3l"),
        ("6-layer RPE", "lm_base_6l", "lm_fd_6l"),
    ];
    let mut t = Table::new(
        "Table 1 (speed): causal LM fused step — TNN baseline vs FD-TNN",
        &["RPE depth", "TNN ms/step", "FD ms/step", "FD speedup", "paper"],
    );
    for (label, base, fd) in pairs {
        eprintln!("measuring {base} vs {fd} ({steps} steps each)...");
        let mb = common::measure(base, steps)?;
        let mf = common::measure(fd, steps)?;
        t.row(&[
            label.to_string(),
            format!("{:.1}", mb.ms_per_step),
            format!("{:.1}", mf.ms_per_step),
            common::speedup_pct(mb.ms_per_step, mf.ms_per_step),
            if label.starts_with('3') { "+10%" } else { "+15%" }.to_string(),
        ]);
    }
    t.print();
    println!("(perplexity rows: `cargo run --release --example train_lm -- --steps 300`)");
    Ok(())
}
