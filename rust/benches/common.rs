//! Shared bench plumbing for the paper-table/figure harnesses.
//!
//! Each measurement runs in a **subprocess** (the bench binary re-execs
//! itself with `--_child <config>`): one PJRT client, one compile, one
//! model — so per-config wall-clock and peak-RSS numbers are clean
//! rather than accumulating across a 15-config sweep in one process.
//! The child prints a single `RESULT {json}` line the parent parses.

#![allow(dead_code)] // each bench binary uses a subset of these helpers

use std::process::Command;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use ski_tnn::coordinator::{batch_for, to_literals};
use ski_tnn::data::{Corpus, Split};
use ski_tnn::runtime::{Engine, ModelState, Task};
use ski_tnn::util::bench::{stats_of, Stats};
use ski_tnn::util::json::{self, Json};

/// One config's measured step performance.  Timing is collected
/// per-step and reduced through [`ski_tnn::util::bench::stats_of`], so
/// every bench in the crate reports the same `Stats` shape (median +
/// p90) instead of hand-rolled means.
#[derive(Debug, Clone)]
pub struct Measured {
    pub config: String,
    /// Per-step wall-clock statistics, seconds.
    pub stats: Stats,
    /// Median step time, ms (`1e3 * stats.p50_s`).
    pub ms_per_step: f64,
    pub steps_per_sec: f64,
    pub peak_rss_mb: f64,
    pub compile_s: f64,
}

impl Measured {
    fn from_stats(config: &str, stats: Stats, peak_rss_mb: f64, compile_s: f64) -> Measured {
        let ms = 1e3 * stats.p50_s;
        Measured {
            config: config.to_string(),
            stats,
            ms_per_step: ms,
            steps_per_sec: if ms > 0.0 { 1e3 / ms } else { f64::INFINITY },
            peak_rss_mb,
            compile_s,
        }
    }
}

/// Peak resident set (VmHWM) of this process, in MiB.
pub fn peak_rss_mb() -> f64 {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// Child-mode entrypoint: if `--_child` is present, run the
/// measurement, print `RESULT {...}` and exit. Call first in `main`.
pub fn run_child_if_requested() {
    let args: Vec<String> = std::env::args().collect();
    let Some(pos) = args.iter().position(|a| a == "--_child") else {
        return;
    };
    let config = args[pos + 1].clone();
    let steps: usize = args
        .iter()
        .position(|a| a == "--_steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    match child_measure(&config, steps) {
        Ok(m) => {
            // The same med/p90 JSON shape as the BENCH_*.json rows.
            println!(
                "RESULT {{\"iters\": {}, \"mean_ms\": {}, \"med_ms\": {}, \"p90_ms\": {}, \
                 \"p95_ms\": {}, \"std_ms\": {}, \"peak_rss_mb\": {}, \"compile_s\": {}}}",
                m.stats.iters,
                1e3 * m.stats.mean_s,
                1e3 * m.stats.p50_s,
                1e3 * m.stats.p90_s,
                1e3 * m.stats.p95_s,
                1e3 * m.stats.std_s,
                m.peak_rss_mb,
                m.compile_s
            );
            std::process::exit(0);
        }
        Err(e) => {
            eprintln!("child error for {config}: {e:#}");
            std::process::exit(1);
        }
    }
}

fn child_measure(config: &str, steps: usize) -> Result<Measured> {
    let engine = Engine::new("artifacts")?;
    let cfg = engine.config(config)?.clone();
    let corpus = match cfg.task {
        Task::Cls => None,
        _ => Some(Arc::new(
            Corpus::generate(0, (cfg.n * cfg.batch * 16).max(200_000)).tokens(),
        )),
    };
    let t0 = Instant::now();
    let mut state = ModelState::init(&engine, config, 0)?;
    let _ = engine.load(config, "step")?;
    let compile_s = t0.elapsed().as_secs_f64();

    let mut src = batch_for(&engine, config, Split::Train, corpus, 1)?;
    let batch = to_literals(&src.next_batch())?;
    // warmup (first execution pays one-off allocs)
    state.step(&batch)?;
    let mut samples = Vec::with_capacity(steps);
    for _ in 0..steps {
        let t1 = Instant::now();
        state.step(&batch)?;
        samples.push(t1.elapsed().as_secs_f64());
    }
    Ok(Measured::from_stats(config, stats_of(&samples), peak_rss_mb(), compile_s))
}

/// Measure one config in a fresh subprocess.
pub fn measure(config: &str, steps: usize) -> Result<Measured> {
    let exe = std::env::current_exe().context("current_exe")?;
    let out = Command::new(exe)
        .args(["--_child", config, "--_steps", &steps.to_string()])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .context("spawning child")?;
    if !out.status.success() {
        return Err(anyhow!(
            "child for {config} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        ));
    }
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .find_map(|l| l.strip_prefix("RESULT "))
        .ok_or_else(|| anyhow!("no RESULT line from child for {config}"))?;
    let v = json::parse(line).map_err(|e| anyhow!("bad child json: {e}"))?;
    let f = |k: &str| v.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    let iters = v.get("iters").and_then(Json::as_usize).unwrap_or(steps);
    let stats = Stats {
        iters,
        mean_s: f("mean_ms") / 1e3,
        p50_s: f("med_ms") / 1e3,
        p90_s: f("p90_ms") / 1e3,
        p95_s: f("p95_ms") / 1e3,
        std_s: f("std_ms") / 1e3,
        total_s: f("mean_ms") / 1e3 * iters as f64,
    };
    Ok(Measured::from_stats(config, stats, f("peak_rss_mb"), f("compile_s")))
}

/// Format a relative speedup of `new` over `base` as `+NN.N%`.
pub fn speedup_pct(base_ms: f64, new_ms: f64) -> String {
    format!("{:+.1}%", 100.0 * (base_ms / new_ms - 1.0))
}
