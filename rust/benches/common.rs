//! Shared bench plumbing for the paper-table/figure harnesses.
//!
//! Each measurement runs in a **subprocess** (the bench binary re-execs
//! itself with `--_child <config>`): one PJRT client, one compile, one
//! model — so per-config wall-clock and peak-RSS numbers are clean
//! rather than accumulating across a 15-config sweep in one process.
//! The child prints a single `RESULT {json}` line the parent parses.

#![allow(dead_code)] // each bench binary uses a subset of these helpers

use std::process::Command;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use ski_tnn::coordinator::{batch_for, to_literals};
use ski_tnn::data::{Corpus, Split};
use ski_tnn::runtime::{Engine, ModelState, Task};
use ski_tnn::util::json::{self, Json};

/// One config's measured step performance.
#[derive(Debug, Clone)]
pub struct Measured {
    pub config: String,
    pub ms_per_step: f64,
    pub steps_per_sec: f64,
    pub peak_rss_mb: f64,
    pub compile_s: f64,
}

/// Peak resident set (VmHWM) of this process, in MiB.
pub fn peak_rss_mb() -> f64 {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// Child-mode entrypoint: if `--_child` is present, run the
/// measurement, print `RESULT {...}` and exit. Call first in `main`.
pub fn run_child_if_requested() {
    let args: Vec<String> = std::env::args().collect();
    let Some(pos) = args.iter().position(|a| a == "--_child") else {
        return;
    };
    let config = args[pos + 1].clone();
    let steps: usize = args
        .iter()
        .position(|a| a == "--_steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    match child_measure(&config, steps) {
        Ok(m) => {
            println!(
                "RESULT {{\"ms_per_step\": {}, \"peak_rss_mb\": {}, \"compile_s\": {}}}",
                m.ms_per_step, m.peak_rss_mb, m.compile_s
            );
            std::process::exit(0);
        }
        Err(e) => {
            eprintln!("child error for {config}: {e:#}");
            std::process::exit(1);
        }
    }
}

fn child_measure(config: &str, steps: usize) -> Result<Measured> {
    let engine = Engine::new("artifacts")?;
    let cfg = engine.config(config)?.clone();
    let corpus = match cfg.task {
        Task::Cls => None,
        _ => Some(Arc::new(
            Corpus::generate(0, (cfg.n * cfg.batch * 16).max(200_000)).tokens(),
        )),
    };
    let t0 = Instant::now();
    let mut state = ModelState::init(&engine, config, 0)?;
    let _ = engine.load(config, "step")?;
    let compile_s = t0.elapsed().as_secs_f64();

    let mut src = batch_for(&engine, config, Split::Train, corpus, 1)?;
    let batch = to_literals(&src.next_batch())?;
    // warmup (first execution pays one-off allocs)
    state.step(&batch)?;
    let t1 = Instant::now();
    for _ in 0..steps {
        state.step(&batch)?;
    }
    let ms = 1e3 * t1.elapsed().as_secs_f64() / steps as f64;
    Ok(Measured {
        config: config.to_string(),
        ms_per_step: ms,
        steps_per_sec: 1e3 / ms,
        peak_rss_mb: peak_rss_mb(),
        compile_s,
    })
}

/// Measure one config in a fresh subprocess.
pub fn measure(config: &str, steps: usize) -> Result<Measured> {
    let exe = std::env::current_exe().context("current_exe")?;
    let out = Command::new(exe)
        .args(["--_child", config, "--_steps", &steps.to_string()])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .context("spawning child")?;
    if !out.status.success() {
        return Err(anyhow!(
            "child for {config} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        ));
    }
    let stdout = String::from_utf8_lossy(&out.stdout);
    let line = stdout
        .lines()
        .find_map(|l| l.strip_prefix("RESULT "))
        .ok_or_else(|| anyhow!("no RESULT line from child for {config}"))?;
    let v = json::parse(line).map_err(|e| anyhow!("bad child json: {e}"))?;
    let f = |k: &str| v.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    let ms = f("ms_per_step");
    Ok(Measured {
        config: config.to_string(),
        ms_per_step: ms,
        steps_per_sec: 1e3 / ms,
        peak_rss_mb: f("peak_rss_mb"),
        compile_s: f("compile_s"),
    })
}

/// Format a relative speedup of `new` over `base` as `+NN.N%`.
pub fn speedup_pct(base_ms: f64, new_ms: f64) -> String {
    format!("{:+.1}%", 100.0 * (base_ms / new_ms - 1.0))
}
