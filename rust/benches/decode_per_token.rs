//! Per-token decode cost: SSM recurrence vs full-context FFT recompute.
//!
//! The claim under test (Qin & Zhong 2023, the decode subsystem's
//! foundation): converting a causal Toeplitz kernel to a diagonal SSM
//! makes per-token generation cost **O(m) — flat in sequence
//! position** — while a server that recomputes the full-context FFT
//! for every emitted token pays O(n log n) that *grows* with context.
//!
//! Two tables:
//! 1. per-token cost across n ∈ {256 … 4096}: the SSM column stays
//!    flat, the FFT-recompute column grows, the window fallback grows
//!    linearly (why it is only a fallback);
//! 2. position-bucket flatness at n = 4096: SSM per-token cost in the
//!    first vs last quarter of the stream is the O(1)-in-position
//!    evidence.
//!
//! Run: `cargo bench --bench decode_per_token`

use std::time::Instant;

use ski_tnn::decode::{DiagonalSsm, KernelDecoder};
use ski_tnn::toeplitz::ToeplitzKernel;
use ski_tnn::util::bench::{fmt_secs, quick_mode, write_bench_json, Bencher, Table};
use ski_tnn::util::json::Json;
use ski_tnn::util::rng::Rng;

/// Smooth exponentially-decaying causal taps (the TNN regime — see
/// paper §4.2 decay results) of length `n`.
fn decay_taps(n: usize) -> Vec<f32> {
    (0..n)
        .map(|t| 0.97f32.powi(t as i32) + 0.5 * 0.80f32.powi(t as i32))
        .collect()
}

fn main() {
    let rank = 16usize;
    // Quick (CI smoke) mode: fewer sizes, tighter iteration budget —
    // the same keys `bench/baseline.json` is recorded with.
    let quick = quick_mode();
    let sizes: &[usize] = if quick { &[256, 512, 1024] } else { &[256, 512, 1024, 2048, 4096] };
    let bench = if quick {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            budget: std::time::Duration::from_millis(500),
        }
    } else {
        Bencher::quick()
    };
    let mut rng = Rng::new(0);

    let mut t = Table::new(
        &format!("per-token decode cost (SSM rank {rank}) vs full-context recompute"),
        &["n", "ssm/token", "window/token", "fft-recompute/token", "fft vs ssm"],
    );
    let mut first_ssm = 0.0f64;
    let mut last_ssm = 0.0f64;
    let mut rows: Vec<Json> = Vec::new();
    for &n in sizes {
        let taps = decay_taps(n);
        let kernel = ToeplitzKernel::from_causal_taps(&taps);
        let ssm = DiagonalSsm::fit(&taps, rank);
        let win = KernelDecoder::window(&taps);
        let x = rng.normals(n);

        // Stream n tokens through the SSM; per-token = total / n.
        let s_ssm = bench.run(|| {
            let mut h = ssm.init_state();
            let mut acc = 0.0f32;
            for &xi in &x {
                acc += ssm.step(&mut h, xi);
            }
            std::hint::black_box(acc);
        });
        // Same stream through the exact sliding window (O(n)/token).
        let s_win = bench.run(|| {
            let mut st = win.init_state();
            let mut acc = 0.0f32;
            for &xi in &x {
                acc += win.step(&mut st, xi).expect("window step");
            }
            std::hint::black_box(acc);
        });
        // Baseline: a server with no decode path recomputes the full
        // n-point FFT apply for every emitted token.
        let s_fft = bench.run(|| {
            std::hint::black_box(kernel.apply_fft(&x));
        });

        let ssm_tok = s_ssm.mean_s / n as f64;
        let win_tok = s_win.mean_s / n as f64;
        let fft_tok = s_fft.mean_s; // one apply per token
        if n == sizes[0] {
            first_ssm = ssm_tok;
        }
        last_ssm = ssm_tok;
        t.row(&[
            n.to_string(),
            fmt_secs(ssm_tok),
            fmt_secs(win_tok),
            fmt_secs(fft_tok),
            format!("{:.0}×", fft_tok / ssm_tok),
        ]);
        // Per-size machine-readable rows (median + p90 ns/op) — the
        // per-token medians divide the whole-stream medians by n.
        for (mode, stats, per_tok) in [
            ("ssm", &s_ssm, 1.0 / n as f64),
            ("window", &s_win, 1.0 / n as f64),
            ("fft_recompute", &s_fft, 1.0),
        ] {
            rows.push(Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("rank", Json::num(rank as f64)),
                ("mode", Json::str(mode)),
                ("med_ns_per_token", Json::num(1e9 * stats.p50_s * per_tok)),
                ("p90_ns_per_token", Json::num(1e9 * stats.p90_s * per_tok)),
            ]));
        }
    }
    t.print();
    match write_bench_json("decode_per_token", rows) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write BENCH_decode_per_token.json: {e}"),
    }
    println!(
        "ssm per-token at n={} vs n={}: {:.2}× (flat ⇒ O(1) in context; \
         fft-recompute grows with n)",
        sizes.last().unwrap(),
        sizes[0],
        last_ssm / first_ssm
    );

    // ---------------- flatness in sequence position ----------------
    let n = if quick { 1024 } else { 4096 };
    let taps = decay_taps(n);
    let ssm = DiagonalSsm::fit(&taps, rank);
    let x = rng.normals(n);
    let buckets = 4;
    let chunk = n / buckets;
    let reps = if quick { 20 } else { 50 };
    let mut secs = vec![0.0f64; buckets];
    let mut sink = 0.0f32;
    for _ in 0..reps {
        let mut h = ssm.init_state();
        for (b, xs) in x.chunks(chunk).enumerate() {
            let t0 = Instant::now();
            for &xi in xs {
                sink += ssm.step(&mut h, xi);
            }
            secs[b] += t0.elapsed().as_secs_f64();
        }
    }
    std::hint::black_box(sink);
    let mut t = Table::new(
        &format!("SSM per-token cost by stream position (n = {n})"),
        &["positions", "per token"],
    );
    for (b, s) in secs.iter().enumerate() {
        t.row(&[
            format!("{}..{}", b * chunk, (b + 1) * chunk),
            fmt_secs(s / (reps * chunk) as f64),
        ]);
    }
    t.print();
    let lo = secs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = secs.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "bucket spread {:.2}× (≈1 ⇒ per-token cost is independent of position: \
         the constant-time decode claim, measured)",
        hi / lo
    );
}
