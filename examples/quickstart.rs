//! Quickstart — the smallest end-to-end tour of the system.
//!
//! 1. Load the AOT artifact manifest and compile one model on the PJRT
//!    CPU client (Layer 3 ⇄ Layer 2 bridge).
//! 2. Train it for a handful of steps on the synthetic corpus.
//! 3. Ask the pure-Rust Toeplitz substrate the paper's core question in
//!    miniature: how well does an r-point asymmetric-SKI factorization
//!    approximate a smooth Toeplitz operator, and what does the
//!    sparse+low-rank split buy?
//!
//! Run with: `cargo run --release --example quickstart`

use anyhow::Result;

use ski_tnn::config::RunConfig;
use ski_tnn::coordinator::Trainer;
use ski_tnn::runtime::Engine;
use ski_tnn::toeplitz::{conv1d, gaussian_kernel, Ski, ToeplitzKernel};

fn main() -> Result<()> {
    // ------------------------------------------------------------------
    // 1+2. Compile & train an FD-TNN for a few steps.
    // ------------------------------------------------------------------
    let run = RunConfig {
        config: "lm_fd_3l".into(),
        steps: 10,
        eval_every: 5,
        eval_batches: 2,
        log_every: 5,
        corpus_bytes: 200_000,
        ..RunConfig::default()
    };
    let engine = Engine::new(&run.artifacts)?;
    println!("PJRT platform: {}", engine.platform());
    let cfg = engine.config(&run.config)?;
    println!(
        "model {}: {} params, {} blocks, n={}, variant={}",
        cfg.name,
        cfg.param_count,
        cfg.blocks,
        cfg.n,
        cfg.variant.as_str()
    );
    let mut trainer = Trainer::new(&engine, run)?;
    let stats = trainer.train()?;
    println!("after 10 steps: val ppl {:.1}\n", stats.ppl);

    // ------------------------------------------------------------------
    // 3. The paper's §3.2 decomposition on the Rust substrate.
    // ------------------------------------------------------------------
    let n = 512;
    // A "spiky near the diagonal, smooth elsewhere" kernel — the shape
    // the paper observes in trained TNNs (their Fig. 2 motivation).
    let spike = |t: i64| if t.unsigned_abs() < 4 { (4 - t.abs()) as f32 * 0.25 } else { 0.0 };
    let smooth = |t: f64| gaussian_kernel(t, 80.0);
    let full = ToeplitzKernel::from_fn(n, |t| spike(t) + smooth(t as f64));

    let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
    let exact = full.apply_fft(&x);

    // sparse branch = 7-tap conv; low-rank branch = r-point SKI
    let w: Vec<f32> = (-3i64..=3).map(spike).collect();
    let sparse_y = conv1d(&x, &w, false);
    println!("SKI approximation error vs rank (n = {n}, sparse filter m = 7):");
    println!("{:>6} {:>14} {:>20}", "r", "low-rank only", "sparse + low-rank");
    for r in [8usize, 16, 32, 64, 128] {
        let ski = Ski::from_kernel(n, r, |t| spike(t.round() as i64) as f32 + smooth(t));
        let ski_smooth = Ski::from_kernel(n, r, smooth);
        let lr_only = ski.apply_sparse(&x);
        let both: Vec<f32> = ski_smooth
            .apply_sparse(&x)
            .iter()
            .zip(sparse_y.iter())
            .map(|(a, b)| a + b)
            .collect();
        let rel = |approx: &[f32]| {
            let num: f32 =
                exact.iter().zip(approx).map(|(a, b)| (a - b) * (a - b)).sum::<f32>();
            let den: f32 = exact.iter().map(|a| a * a).sum::<f32>();
            (num / den).sqrt()
        };
        println!("{:>6} {:>14.5} {:>20.5}", r, rel(&lr_only), rel(&both));
    }
    println!("\n→ the sparse+low-rank split (paper §3.2) absorbs the diagonal spike that");
    println!("  interpolation alone cannot, exactly the paper's motivation for T_sparse.");
    Ok(())
}
