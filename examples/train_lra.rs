//! Long-Range-Arena driver — the paper's Table 2 / Fig 1a experiments.
//!
//! Trains the three TNO variants (TNN baseline, SKI-TNN, FD-TNN) on
//! the synthetic LRA task suite and reports the accuracy grid plus the
//! per-variant speed, the two axes of the paper's Fig 1a bubble plot.
//!
//! Usage:
//! ```text
//! cargo run --release --example train_lra -- \
//!     --tasks text,listops --variants base,ski,fd --steps 200 --out-dir runs/lra
//! cargo run --release --example train_lra --            # all 5 tasks
//! ```

use anyhow::Result;

use ski_tnn::config::RunConfig;
use ski_tnn::coordinator::Trainer;
use ski_tnn::runtime::Engine;
use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(false);
    let tasks = args.list_or(
        "tasks",
        &["text", "listops", "retrieval", "pathfinder", "image"],
    );
    let variants = args.list_or("variants", &["base", "ski", "fd"]);
    let steps = args.usize_or("steps", 200);

    let mut base_run = RunConfig::default();
    base_run.apply_args(&args);
    base_run.steps = steps;
    if args.get("eval-batches").is_none() {
        base_run.eval_batches = 16; // accuracy needs more eval examples
    }

    let engine = Engine::new(&base_run.artifacts)?;
    println!("platform: {} | LRA suite (synthetic generators, n=1024)", engine.platform());

    // accuracy grid [task][variant] + speed grid
    let mut acc = vec![vec![f64::NAN; variants.len()]; tasks.len()];
    let mut sps = vec![vec![f64::NAN; variants.len()]; tasks.len()];

    for (ti, task) in tasks.iter().enumerate() {
        for (vi, variant) in variants.iter().enumerate() {
            let config = format!("lra_{task}_{variant}");
            if engine.config(&config).is_err() {
                println!("skipping {config} (not in manifest)");
                continue;
            }
            let mut run = base_run.clone();
            run.config = config.clone();
            let mut trainer = Trainer::new(&engine, run)?;
            println!("\n=== training {config} ({steps} steps) ===");
            let stats = trainer.train()?;
            acc[ti][vi] = 100.0 * stats.acc;
            sps[ti][vi] = trainer
                .metrics
                .series("final", "steps_per_sec")
                .last()
                .map(|(_, v)| *v)
                .unwrap_or(f64::NAN);
        }
    }

    let headers: Vec<&str> =
        std::iter::once("task").chain(variants.iter().map(|v| v.as_str())).collect();
    let mut t_acc = Table::new(
        &format!("LRA accuracy %, {steps} steps (paper Table 2 shape: FD ≥ TNN ≥ SKI)"),
        &headers,
    );
    let mut t_sps = Table::new(
        "LRA training steps/sec (paper Fig 1a x-axis: SKI & FD faster than TNN)",
        &headers,
    );
    for (ti, task) in tasks.iter().enumerate() {
        t_acc.row(
            &std::iter::once(task.clone())
                .chain(acc[ti].iter().map(|a| format!("{a:.1}")))
                .collect::<Vec<_>>(),
        );
        t_sps.row(
            &std::iter::once(task.clone())
                .chain(sps[ti].iter().map(|s| format!("{s:.2}")))
                .collect::<Vec<_>>(),
        );
    }
    // column averages (the paper's Avg row)
    let avg_row = |grid: &[Vec<f64>]| -> Vec<String> {
        std::iter::once("avg".to_string())
            .chain((0..variants.len()).map(|vi| {
                let vals: Vec<f64> = grid
                    .iter()
                    .map(|r| r[vi])
                    .filter(|v| v.is_finite())
                    .collect();
                format!("{:.1}", vals.iter().sum::<f64>() / vals.len().max(1) as f64)
            }))
            .collect()
    };
    t_acc.row(&avg_row(&acc));
    t_acc.print();
    t_sps.print();
    Ok(())
}
