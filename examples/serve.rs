//! Serving demo — dynamic batching over the `logits` artifact.
//!
//! Loads (or initializes) a model, starts the dynamic batcher, and
//! drives it with concurrent synthetic clients at a configurable
//! arrival rate, reporting throughput, batch fill, and latency
//! percentiles — the serving-side counterpart of the paper's speed
//! claims (an FD/SKI TNO also shrinks inference latency, since the
//! same TNO runs inside the `logits` entry).
//!
//! Usage:
//! ```text
//! cargo run --release --example serve -- --config lra_text_fd \
//!     --requests 400 --clients 8 --max-wait-ms 2
//! cargo run --release --example serve -- --config lm_fd_3l \
//!     --resume runs/lm/lm_fd_3l_step300.ckpt
//! ```

use std::time::{Duration, Instant};

use anyhow::Result;

use ski_tnn::config::RunConfig;
use ski_tnn::runtime::{Engine, ModelState};
use ski_tnn::server::{serve_model, Batcher, ServerConfig};
use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;
use ski_tnn::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(false);
    let mut rc = RunConfig::default();
    rc.config = "lra_text_fd".into();
    rc.apply_args(&args);
    let requests = args.usize_or("requests", 400);
    let clients = args.usize_or("clients", 8);
    let think_us = args.u64_or("think-us", 500);

    let engine = Engine::new(&rc.artifacts)?;
    let cfg = engine.config(&rc.config)?.clone();
    let state = match &rc.resume {
        Some(p) => ModelState::load(&engine, p)?,
        None => ModelState::init(&engine, &rc.config, rc.seed as u32)?,
    };
    engine.load(&rc.config, "logits")?; // compile before load arrives

    let server_cfg = ServerConfig {
        max_batch: cfg.batch,
        n: cfg.n,
        max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        queue_depth: args.usize_or("queue-depth", 64),
        buckets: Vec::new(),
        ..ServerConfig::default()
    };
    println!(
        "serving {} (batch {}, n {}, {} classes/vocab) · {clients} clients · {requests} requests",
        rc.config,
        cfg.batch,
        cfg.n,
        if cfg.task == ski_tnn::runtime::Task::Cls { cfg.num_classes } else { cfg.vocab },
    );

    let batcher = Batcher::new(server_cfg);
    let handle = batcher.handle();
    let per_client = requests / clients;
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            let n = cfg.n;
            let seed = rc.seed + c as u64;
            std::thread::spawn(move || -> (Vec<f64>, Vec<f64>) {
                let mut rng = Rng::new(seed);
                let mut lat = Vec::with_capacity(per_client);
                let mut queued = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let len = 8 + rng.below(n.saturating_sub(8).max(1));
                    let ids: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                    let t0 = Instant::now();
                    let resp = h.infer(ids).expect("infer");
                    lat.push(t0.elapsed().as_secs_f64());
                    queued.push(resp.queued.as_secs_f64());
                    std::thread::sleep(Duration::from_micros(think_us));
                }
                (lat, queued)
            })
        })
        .collect();
    drop(handle);

    let t0 = Instant::now();
    let stats = batcher.run(serve_model(&engine, &state))?;
    let wall = t0.elapsed().as_secs_f64();

    let mut lats = Vec::new();
    let mut queueds = Vec::new();
    for w in workers {
        let (l, q) = w.join().unwrap();
        lats.extend(l);
        queueds.extend(q);
    }
    lats.sort_by(|a, b| a.total_cmp(b));
    queueds.sort_by(|a, b| a.total_cmp(b));
    let pct = |v: &[f64], p: f64| v[((v.len() as f64 - 1.0) * p) as usize];

    let mut t = Table::new("serving summary", &["metric", "value"]);
    t.row(&["requests".into(), format!("{}", stats.requests)]);
    t.row(&["batches".into(), format!("{}", stats.batches)]);
    t.row(&[
        "mean batch fill".into(),
        format!("{:.1}%", 100.0 * stats.mean_batch_fill(cfg.batch)),
    ]);
    t.row(&["throughput".into(), format!("{:.1} req/s", stats.requests as f64 / wall)]);
    t.row(&["latency p50".into(), format!("{:.1} ms", 1e3 * pct(&lats, 0.5))]);
    t.row(&["latency p95".into(), format!("{:.1} ms", 1e3 * pct(&lats, 0.95))]);
    t.row(&["latency p99".into(), format!("{:.1} ms", 1e3 * pct(&lats, 0.99))]);
    t.row(&["queue wait p95".into(), format!("{:.1} ms", 1e3 * pct(&queueds, 0.95))]);
    t.row(&[
        "exec time share".into(),
        format!("{:.1}% of wall", 100.0 * stats.exec_seconds / wall),
    ]);
    t.print();
    Ok(())
}
