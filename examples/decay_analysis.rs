//! Smoothness ⇒ decay — reproduces the paper's Figs 4–6 and validates
//! Theorems 2–4 numerically.
//!
//! For each activation (GeLU / SiLU / ReLU) we random-init the FD RPE
//! MLP (same shape as `python/compile/rpe.py`), sample its frequency
//! response on the rFFT grid `ω_m = mπ/n`, inverse-transform with the
//! pure-Rust `dsp::irfft`, and measure how fast the impulse response
//! decays:
//!
//! * GeLU — entire ⇒ super-exponential decay (Theorem 2): the fitted
//!   log-slope keeps steepening and the response is ≈0 well before n.
//! * SiLU — C^∞ ⇒ super-polynomial decay (Theorem 3).
//! * ReLU — continuous only ⇒ merely square-summable (Theorem 4): mass
//!   spreads across the full window.
//!
//! Prints per-band envelope tables (the figures' right-hand panels in
//! numbers) and writes `<out-dir>/decay_<act>.csv` when `--out-dir` is
//! given.
//!
//! Usage: `cargo run --release --example decay_analysis -- --n 512`

use anyhow::Result;

use ski_tnn::dsp::irfft;
use ski_tnn::nn::{Act, Mlp};
use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;
use ski_tnn::util::rng::Rng;

/// Band-wise max |k[t]| envelope of an impulse response.
fn envelope(k: &[f32], bands: &[(usize, usize)]) -> Vec<f64> {
    bands
        .iter()
        .map(|&(lo, hi)| {
            k[lo..hi.min(k.len())].iter().map(|v| v.abs() as f64).fold(0.0, f64::max)
        })
        .collect()
}

fn main() -> Result<()> {
    let args = Args::parse(false);
    let n = args.usize_or("n", 512);
    let d = args.usize_or("channels", 8);
    let seeds = args.usize_or("seeds", 8);
    assert!(n >= 2, "--n must be at least 2");

    let bands: Vec<(usize, usize)> =
        vec![(1, 8), (8, 16), (16, 32), (32, 64), (64, 128), (128, 256), (256, n)];
    let band_names: Vec<String> =
        bands.iter().map(|&(lo, hi)| format!("t∈[{lo},{hi})")).collect();
    let mut headers: Vec<&str> = vec!["activation"];
    headers.extend(band_names.iter().map(|s| s.as_str()));
    headers.push("tail/peak");

    let mut table = Table::new(
        &format!("Impulse-response envelope, FD RPE MLP, n={n} (paper Figs 4-6, Thms 2-4)"),
        &headers,
    );

    let mut csv_rows: Vec<(String, Vec<f64>)> = Vec::new();
    for act in [Act::Gelu, Act::Silu, Act::Relu] {
        // average the envelope over several random inits and channels
        let mut acc = vec![0.0f64; bands.len()];
        let mut mean_impulse = vec![0.0f64; n];
        for s in 0..seeds {
            let mut rng = Rng::new(0xDECA + s as u64);
            let mlp = Mlp::init(&mut rng, &[1, 32, 32, d], act, 0.3);
            // frequency response on ω_m = mπ/n, m = 0..n  (n+1 bins)
            let grid: Vec<f64> = (0..=n).map(|m| m as f64 / n as f64).collect();
            let rows = mlp.forward_grid(&grid);
            for ch in 0..d {
                let khat: Vec<ski_tnn::dsp::Complex> = rows
                    .iter()
                    .map(|r| ski_tnn::dsp::Complex::new(r[ch], 0.0))
                    .collect();
                // real even spectrum of length n+1 → irfft to 2n; keep
                // non-negative lags 0..n (the response is symmetric)
                let kt = irfft(&khat, 2 * n);
                let k: Vec<f32> = kt[..n].to_vec();
                let env = envelope(&k, &bands);
                for (a, e) in acc.iter_mut().zip(env.iter()) {
                    *a += e;
                }
                for (mi, &v) in mean_impulse.iter_mut().zip(k.iter()) {
                    *mi += (v as f64).abs();
                }
            }
        }
        let denom = (seeds * d) as f64;
        for a in acc.iter_mut() {
            *a /= denom;
        }
        for v in mean_impulse.iter_mut() {
            *v /= denom;
        }
        let tail_ratio = acc.last().unwrap() / acc.first().unwrap().max(1e-30);
        table.row(
            &std::iter::once(format!("{act:?}"))
                .chain(acc.iter().map(|v| format!("{v:.2e}")))
                .chain([format!("{tail_ratio:.2e}")])
                .collect::<Vec<_>>(),
        );
        csv_rows.push((format!("{act:?}").to_lowercase(), mean_impulse));
    }
    table.print();
    println!("expected ordering (Thms 2-4): tail/peak GeLU ≪ SiLU ≪ ReLU");

    if let Some(dir) = args.get("out-dir") {
        std::fs::create_dir_all(dir)?;
        for (name, imp) in &csv_rows {
            let mut csv = String::from("t,mean_abs_k\n");
            for (t, v) in imp.iter().enumerate() {
                csv.push_str(&format!("{t},{v}\n"));
            }
            let path = format!("{dir}/decay_{name}.csv");
            std::fs::write(&path, csv)?;
            println!("wrote {path}");
        }
    }
    Ok(())
}
