//! Streaming generation demo — the decode subsystem end to end.
//!
//! Builds a pure-Rust streaming TNN LM (causal Toeplitz kernels
//! converted to diagonal SSMs where the fit is tight, exact sliding
//! windows elsewhere), generates a continuation for a prompt, then
//! runs a small continuous-batching load test through the
//! [`GenScheduler`] and prints server-side stats.
//!
//! Usage:
//! ```text
//! cargo run --release --example generate -- --prompt "ski to go " \
//!     --tokens 96 --temperature 0.9 --top-k 40
//! cargo run --release --example generate -- --clients 6 --requests 24
//! ```
//!
//! [`GenScheduler`]: ski_tnn::server::GenScheduler

use anyhow::Result;

use ski_tnn::decode::model::{detokenize, tokenize};
use ski_tnn::decode::{DecodeModel, DecodeModelConfig, DecodePolicy, Sampler, Session};
use ski_tnn::server::{GenConfig, GenParams, GenScheduler};
use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;
use ski_tnn::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::parse(false);
    let cfg = DecodeModelConfig {
        d: args.usize_or("d", 32),
        blocks: args.usize_or("blocks", 2),
        n: args.usize_or("n", 512),
        policy: DecodePolicy {
            rank: args.usize_or("rank", 16),
            max_rel_residual: args.f64_or("max-rel-residual", 0.05),
        },
        seed: args.u64_or("seed", 0),
        ..DecodeModelConfig::default()
    };
    let model = DecodeModel::new(cfg);
    let (ssm, win) = model.decoder_mix();
    println!(
        "model: d={} blocks={} n={} → {ssm} SSM decoders / {win} window fallbacks, \
         ~{} token-mix madds per token",
        cfg.d,
        cfg.blocks,
        cfg.n,
        model.decode_cost_per_token()
    );

    // ---- one session, driven directly (no scheduler) ----
    let prompt_text = args.str_or("prompt", "the toeplitz operator ");
    let sampler = Sampler::new(
        args.f64_or("temperature", 0.9) as f32,
        args.usize_or("top-k", 40),
        cfg.seed,
    );
    let max_new = args.usize_or("tokens", 96);
    let t0 = std::time::Instant::now();
    let mut session = Session::new(&model, 0, &tokenize(&prompt_text), sampler, max_new)?;
    let prefill = t0.elapsed();
    let t1 = std::time::Instant::now();
    while !session.done() {
        session.step(&model)?;
    }
    let decode = t1.elapsed();
    println!("\nprompt : {prompt_text:?}");
    println!("output : {:?}", detokenize(session.generated()));
    println!(
        "prefill {:.2} ms, decode {:.3} ms/token ({:.0} tok/s), session state {} f32s",
        1e3 * prefill.as_secs_f64(),
        1e3 * decode.as_secs_f64() / max_new.max(1) as f64,
        max_new as f64 / decode.as_secs_f64().max(1e-12),
        session.state_size()
    );

    // ---- continuous batching across many sessions ----
    let clients = args.usize_or("clients", 4);
    let requests = args.usize_or("requests", clients * 4);
    let per_client = (requests / clients).max(1);
    let sched = GenScheduler::new(GenConfig {
        max_sessions: args.usize_or("slots", 8),
        queue_depth: args.usize_or("queue-depth", 64),
        max_new_cap: 512,
        threads: args.usize_or("threads", 0),
        ..GenConfig::default()
    });
    let handle = sched.handle();
    let params = GenParams {
        max_new: args.usize_or("tokens", 96).min(512),
        temperature: args.f64_or("temperature", 0.9) as f32,
        top_k: args.usize_or("top-k", 40),
        seed: cfg.seed,
    };
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(1 + c as u64);
                for _ in 0..per_client {
                    let len = 4 + rng.below(24);
                    let prompt: Vec<i32> = (0..len).map(|_| rng.below(256) as i32).collect();
                    let p = GenParams { seed: rng.next_u64(), ..params };
                    h.generate(prompt, p).expect("generate");
                }
            })
        })
        .collect();
    drop(handle);
    let t2 = std::time::Instant::now();
    let stats = sched.run(&model)?;
    let wall = t2.elapsed().as_secs_f64();
    for w in workers {
        w.join().unwrap();
    }

    let (p50, p95, p99) = stats.queue_percentiles();
    let mut t = Table::new("continuous batching summary", &["metric", "value"]);
    t.row(&["sessions".into(), format!("{}", stats.sessions)]);
    t.row(&["tokens".into(), format!("{}", stats.tokens)]);
    t.row(&["scheduler ticks".into(), format!("{}", stats.ticks)]);
    t.row(&["mean concurrency".into(), format!("{:.2}", stats.mean_concurrency())]);
    t.row(&["throughput (decode)".into(), format!("{:.0} tok/s", stats.tokens_per_sec())]);
    let wall_tps = format!("{:.0} tok/s", stats.tokens as f64 / wall.max(1e-9));
    t.row(&["throughput (wall)".into(), wall_tps]);
    t.row(&["queue wait p50".into(), format!("{:.2} ms", 1e3 * p50)]);
    t.row(&["queue wait p95".into(), format!("{:.2} ms", 1e3 * p95)]);
    t.row(&["queue wait p99".into(), format!("{:.2} ms", 1e3 * p99)]);
    t.print();
    Ok(())
}
