//! End-to-end pre-training driver — the paper's Wikitext-103 section.
//!
//! Trains TNO variants side-by-side on the synthetic grammar corpus and
//! reports the paper's comparisons:
//!
//! * **Table 1 rows** — final val perplexity per variant (TNN baseline
//!   vs FD-TNN), plus measured steps/sec and the FD speedup.
//! * **Fig 7b / 8 / 9 curves** — val-PPL-vs-iteration series written to
//!   `<out-dir>/<config>_metrics.{csv,json}`.
//! * **Fig 7a** — perplexity vs inference length via the `fwd_n{L}`
//!   artifacts (`--ppl-vs-len`, causal 3-layer configs only).
//!
//! Usage:
//! ```text
//! cargo run --release --example train_lm -- \
//!     --mode causal --variants base,fd --steps 300 --out-dir runs/lm
//! cargo run --release --example train_lm -- --mode bidir \
//!     --variants base,fd,ski --steps 200 --out-dir runs/lm_bidir
//! cargo run --release --example train_lm -- --ppl-vs-len --steps 150
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};

use ski_tnn::config::RunConfig;
use ski_tnn::coordinator::{evaluate, Trainer};
use ski_tnn::data::{BatchSource, CausalLmStream, Corpus, Split};
use ski_tnn::runtime::Engine;
use ski_tnn::util::bench::Table;
use ski_tnn::util::cli::Args;

fn config_name(mode: &str, variant: &str, rpe: usize) -> Result<String> {
    Ok(match (mode, variant) {
        ("causal", "base") => format!("lm_base_{rpe}l"),
        ("causal", "fd") => format!("lm_fd_{rpe}l"),
        ("causal", "ski") => {
            bail!("SKI-TNO is bidirectional-only (paper Appendix B); use --mode bidir")
        }
        ("bidir", "base") => format!("lm_bidir_base_{rpe}l"),
        ("bidir", "fd") => format!("lm_bidir_fd_{rpe}l"),
        ("bidir", "ski") => "lm_bidir_ski".to_string(),
        (m, v) => bail!("unknown mode/variant {m}/{v}"),
    })
}

fn main() -> Result<()> {
    let args = Args::parse(false);
    let mode = args.str_or("mode", "causal");
    let variants = args.list_or("variants", &["base", "fd"]);
    let rpe = args.usize_or("rpe-layers", 3);
    let steps = args.usize_or("steps", 300);
    let seed = args.u64_or("seed", 0);

    let mut base_run = RunConfig::default();
    base_run.apply_args(&args);
    base_run.steps = steps;
    base_run.seed = seed;

    let engine = Engine::new(&base_run.artifacts)?;
    println!(
        "platform: {} | corpus: {} bytes (synthetic grammar)",
        engine.platform(),
        base_run.corpus_bytes
    );

    let mut table = Table::new(
        &format!(
            "Pre-training ({mode}, {rpe}-layer RPE, {steps} steps) — paper Table 1 / Figs 7-9"
        ),
        &["variant", "config", "final val PPL", "steps/s", "vs base"],
    );
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    let mut trained: Vec<(String, Trainer)> = Vec::new();

    for variant in &variants {
        let config = config_name(&mode, variant, rpe)?;
        let mut run = base_run.clone();
        run.config = config.clone();
        let mut trainer = Trainer::new(&engine, run)?;
        println!("\n=== training {config} ===");
        let stats = trainer.train()?;
        let sps = trainer
            .metrics
            .series("final", "steps_per_sec")
            .last()
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN);
        rows.push((variant.clone(), stats.ppl, sps));
        trained.push((config, trainer));
    }

    let base_sps = rows.iter().find(|(v, _, _)| v == "base").map(|(_, _, s)| *s);
    for ((variant, ppl, sps), (config, _)) in rows.iter().zip(trained.iter()) {
        let speedup = base_sps
            .map(|b| format!("{:+.1}%", 100.0 * (sps / b - 1.0)))
            .unwrap_or_else(|| "—".into());
        table.row(&[
            variant.clone(),
            config.clone(),
            format!("{ppl:.2}"),
            format!("{sps:.2}"),
            speedup,
        ]);
    }
    table.print();

    // ------------------------------------------------------------------
    // Fig 7a: perplexity vs inference length (causal 3-layer configs
    // carry fwd_n{64,128,384,512} artifacts).
    // ------------------------------------------------------------------
    if args.flag("ppl-vs-len") {
        if mode != "causal" || rpe != 3 {
            bail!("--ppl-vs-len needs --mode causal --rpe-layers 3 (extra lowerings)");
        }
        let corpus = Arc::new(Corpus::generate(seed, base_run.corpus_bytes).tokens());
        let mut t7 = Table::new(
            "PPL vs inference length (paper Fig 7a; trained at n=256, warp extrapolation)",
            &["config", "n=64", "n=128", "n=256", "n=384", "n=512"],
        );
        for (config, trainer) in &trained {
            let cfg = engine.config(config)?;
            let mut cells = vec![config.clone()];
            for len in [64usize, 128, 256, 384, 512] {
                let entry =
                    if len == cfg.n { "fwd".to_string() } else { format!("fwd_n{len}") };
                if !cfg.entries.contains_key(&entry) {
                    cells.push("—".into());
                    continue;
                }
                let mut src: Box<dyn BatchSource> = Box::new(CausalLmStream::new(
                    corpus.clone(),
                    Split::Val,
                    cfg.batch,
                    len,
                    seed + 1,
                ));
                let stats = evaluate(
                    &engine,
                    &trainer.state,
                    &entry,
                    src.as_mut(),
                    base_run.eval_batches,
                )?;
                cells.push(format!("{:.2}", stats.ppl));
            }
            t7.row(&cells);
        }
        t7.print();
    }

    // Fig 7b/8/9 series live in the metrics files when --out-dir is set.
    if let Some(dir) = &base_run.out_dir {
        println!("\nval-PPL-vs-iteration curves written under {}", dir.display());
    }
    Ok(())
}
